"""Substrate tests: data pipeline, optimizer, checkpoint/restart,
elastic restore, failure handling, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, MemmapBackend, SyntheticBackend, TokenPipeline
from repro.dist import collectives as col
from repro.ft.checkpoint import CheckpointManager, restore_latest, save_checkpoint
from repro.ft.elastic import FailureSimulator, elastic_restore
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         dequantize_state, quantize_state)
from repro.optim.schedules import cosine_schedule


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_deterministic_and_seekable():
    cfg = DataConfig(seq_len=8, global_batch=4)
    be = SyntheticBackend(vocab=100)
    a = be.batch(cfg, 5)
    b = be.batch(cfg, 5)
    np.testing.assert_array_equal(a["ids"], b["ids"])
    pipe = TokenPipeline(be, cfg)
    first = [next(pipe)["ids"] for _ in range(3)]
    pipe.seek(1)
    again = next(pipe)["ids"]
    np.testing.assert_array_equal(again, first[1])


def test_host_sharding_partitions_samples():
    be = SyntheticBackend(vocab=100)
    c0 = DataConfig(seq_len=8, global_batch=4, n_hosts=2, host_index=0)
    c1 = DataConfig(seq_len=8, global_batch=4, n_hosts=2, host_index=1)
    b0, b1 = be.batch(c0, 3), be.batch(c1, 3)
    assert b0["ids"].shape == (2, 8)
    assert not np.array_equal(b0["ids"], b1["ids"])


def test_memmap_backend_roundtrip(tmp_path):
    S = 8
    tokens = np.arange(10 * (S + 1), dtype=np.int32)
    path = tmp_path / "tokens.bin"
    tokens.tofile(path)
    be = MemmapBackend(str(path), seq_len=S)
    cfg = DataConfig(seq_len=S, global_batch=2)
    b = be.batch(cfg, 0)
    np.testing.assert_array_equal(b["ids"][0], tokens[:S])
    np.testing.assert_array_equal(b["labels"][0], tokens[1:S + 1])


def test_pipeline_state_dict_resume():
    cfg = DataConfig(seq_len=8, global_batch=4)
    pipe = TokenPipeline(SyntheticBackend(100), cfg)
    next(pipe), next(pipe)
    st_ = pipe.state_dict()
    want = next(pipe)["ids"]
    pipe2 = TokenPipeline(SyntheticBackend(100), cfg)
    pipe2.load_state_dict(st_)
    np.testing.assert_array_equal(next(pipe2)["ids"], want)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params, cfg)
    for i in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_quantize_state_roundtrip_bounded_error(seed):
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (300,))) * 10
    q = quantize_state(v, block=64)
    back = dequantize_state(q, v.shape)
    # sqrt code map: |v' - v| <= d/dv[(127 sqrt(v/s))^-1 step] ~
    #   2*sqrt(v*s)*(0.5/127) + (0.5/127)^2 * s
    blocks = jnp.pad(v, (0, (-300) % 64)).reshape(-1, 64)
    scale = jnp.repeat(jnp.max(blocks, axis=1), 64)[:300]
    tol = jnp.sqrt(jnp.maximum(v, 0.0) * scale) / 127.0 + scale / 127 ** 2
    assert bool(jnp.all(jnp.abs(back - v) <= tol + 1e-9))


def test_quantize_state_small_values_not_zeroed():
    """The sqrt map must keep tiny entries nonzero when the block max is
    large — the linear map's zero-rounding made m/sqrt(v) explode under
    compressed-gradient noise (observed divergence)."""
    v = jnp.asarray([1e-4] * 63 + [10.0])
    back = dequantize_state(quantize_state(v, block=64), v.shape)
    assert float(back[0]) > 0.0


def test_quantized_adamw_tracks_full_precision():
    cfg_f = AdamWConfig(lr=0.05, weight_decay=0.0)
    cfg_q = AdamWConfig(lr=0.05, weight_decay=0.0, quantized=True, block=32)
    p_f = {"w": jnp.ones((64,)) * 2.0}
    p_q = {"w": jnp.ones((64,)) * 2.0}
    o_f, o_q = adamw_init(p_f, cfg_f), adamw_init(p_q, cfg_q)
    for i in range(30):
        g = {"w": 2 * p_f["w"]}
        p_f, o_f, _ = adamw_update(p_f, g, o_f, cfg_f)
        gq = {"w": 2 * p_q["w"]}
        p_q, o_q, _ = adamw_update(p_q, gq, o_q, cfg_q)
    assert float(jnp.max(jnp.abs(p_f["w"] - p_q["w"]))) < 0.05


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, warmup=10, total=100, peak=1.0))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup rises
    assert abs(max(lrs) - 1.0) < 0.01
    assert lrs[-1] < 0.2                   # decays toward the floor


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compressed_psum_error_feedback_unbiased():
    """With error feedback, the accumulated compressed sum tracks the true
    sum over steps (bias is re-injected)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    err = None
    acc_c, acc_t = jnp.zeros_like(x), jnp.zeros_like(x)
    for i in range(20):
        red, err = col.compressed_psum(x, "data", err)   # no mesh: size-1
        acc_c += red
        acc_t += x
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.02, rel


# ---------------------------------------------------------------------------
# checkpoint / restart / elastic
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 12, tree, data_state={"step": 12})
    step, back, ds = restore_latest(str(tmp_path), tree)
    assert step == 12 and ds == {"step": 12}
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    # a crashed save leaves a .tmp dir — restore must ignore it
    os.makedirs(tmp_path / "step_00000002.tmp")
    step, _, _ = restore_latest(str(tmp_path), tree)
    assert step == 1


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    mgr._gc()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_elastic_restore_resharding(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    out = elastic_restore(str(tmp_path), tree)
    assert out is not None and out[0] == 3


def test_failure_simulator_fires_once():
    sim = FailureSimulator(crash_steps=(5,))
    for s in range(5):
        sim.maybe_fail(s)
    with pytest.raises(RuntimeError):
        sim.maybe_fail(5)
    sim.maybe_fail(5)                      # recovered: no second crash
    assert sim.injected == [("crash", 5)]


def test_train_loop_crash_restart_end_to_end(tmp_path):
    """Full loop: crash mid-run, restore from checkpoint, finish, and the
    data cursor resumes exactly."""
    from repro.configs import get_smoke_config
    from repro.core.strategies import get_strategy
    from repro.models.layers import MeshInfo
    from repro.models.registry import build_model
    from repro.train import (TrainLoopConfig, TrainStepConfig,
                             build_train_step, train_loop)
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    B, S = 2, 16
    step_fn, segs, binputs, init_opt = build_train_step(
        model, get_strategy("sequential"), B, S,
        TrainStepConfig(optimizer=AdamWConfig(lr=1e-3), remat=False,
                        warmup=1, total_steps=20))
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    opt = init_opt(params)
    pipe = TokenPipeline(SyntheticBackend(cfg.vocab),
                         DataConfig(seq_len=S, global_batch=B))

    def to_dev(b):
        return {"ids": jnp.asarray(b["ids"]),
                "labels": jnp.asarray(b["labels"]),
                "positions": jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), (B, S))}

    sim = FailureSimulator(crash_steps=(6,))
    p2, o2, hist = train_loop(
        jax.jit(step_fn), params, opt, pipe,
        TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path), ckpt_every=4,
                        log_every=100),
        failure_sim=sim, to_device=to_dev)
    assert sim.injected == [("crash", 6)]
    steps_run = [h["step"] for h in hist]
    assert steps_run[-1] == 9
    # steps 4,5 re-run after restoring the step-4 checkpoint
    assert steps_run.count(4) == 2 and steps_run.count(5) == 2
