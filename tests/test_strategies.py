"""Strategy tests: every paper strategy × every arch family must be
numerically transparent, and each strategy's structural signature
(split/merge/fusion/overlap order) must actually appear in its plan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_smoke_config
from repro.core import partition, record_plan
from repro.core.scheduler import ScheduleContext
from repro.core.strategies import STRATEGIES, get_strategy, tokens_of
from repro.models.base import build_forward
from repro.models.layers import MeshInfo
from repro.models.registry import build_model

B, S = 4, 16
STRATS = ["sequential", "nanoflow", "dbo", "sbo", "tokenweave", "comet",
          "flux", "dynamic"]
FAMS = ["chatglm3-6b", "deepseek-moe-16b", "mamba2-2.7b", "zamba2-1.2b",
        "whisper-tiny", "qwen2-vl-7b"]


def loss_of(arch, strat_name, **kw):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, binputs = model.build_segments("train", B, S)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    strat = get_strategy(strat_name, **kw)
    fwd = build_forward(segs, strat,
                        ScheduleContext(local_batch=B, seq_len=S,
                                        phase="train", arch=arch))
    out = fwd(params, make_batch(binputs))
    return float(jnp.sum(out["loss_sum"]) / jnp.sum(out["token_count"]))


@pytest.mark.parametrize("arch", FAMS)
@pytest.mark.parametrize("strat", STRATS)
def test_strategy_transparency(arch, strat):
    kw = {"min_tokens": 1} if strat in ("nanoflow", "dbo") else {}
    base = loss_of(arch, "sequential")
    got = loss_of(arch, strat, **kw)
    assert abs(got - base) / max(abs(base), 1e-9) < 2e-2, (got, base)


def plan_for(arch, strat_name, **kw):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("train", B, S)
    strat = get_strategy(strat_name, **kw)
    seg = [x for x in segs if "layer" in x.name][-1]
    g = seg.graph
    if strat.partition_rules():
        g = partition(g, strat.partition_rules(), default_depth=2)
    return record_plan(g, strat, ScheduleContext(
        local_batch=B, seq_len=S, phase="train", arch=arch)), g


def test_nanoflow_splits():
    plan, _ = plan_for("chatglm3-6b", "nanoflow", min_tokens=1)
    assert plan.split_sizes == (2, 2)


def test_nanoflow_threshold_falls_back():
    plan, _ = plan_for("chatglm3-6b", "nanoflow", min_tokens=10 ** 9)
    assert plan.split_sizes == ()          # paper Fig. 2a: no small-batch split


def test_dbo_merges_attention_splits_moe():
    plan, g = plan_for("deepseek-moe-16b", "dbo", min_tokens=1)
    assert plan.split_sizes == (2, 2)
    kinds = {}
    for st in plan.steps:
        name = g.nodes[st.handles[0].oid].name
        kinds.setdefault(st.kind, []).append(name)
    assert any("attention" in n for n in kinds.get("merged", []))
    assert any("moe" in n for n in kinds.get("exec", []))
    # canonical interleave: a dispatch of one mb precedes the other mb's
    # expert GEMM (the overlap window)
    order = [(st.kind, g.nodes[st.handles[0].oid].name, st.handles[0].mb)
             for st in plan.steps]
    disp = [i for i, (k, n, m) in enumerate(order) if "dispatch" in n]
    ffn = [i for i, (k, n, m) in enumerate(order) if "expert_ffn" in n]
    assert disp and ffn and disp[1] < ffn[-1]


def test_sbo_reorders_independent_compute_behind_network():
    plan, g = plan_for("deepseek-moe-16b", "sbo")
    names = [g.nodes[st.handles[0].oid].name for st in plan.steps]
    res = [g.nodes[st.handles[0].oid].resource for st in plan.steps]
    # at least one network op is directly followed by a non-dependent
    # compute/memory op
    ok = any(res[i] == "network" and res[i + 1] != "network"
             and not (set(g.nodes[plan.steps[i].handles[0].oid].outputs)
                      & set(g.nodes[plan.steps[i + 1].handles[0].oid].inputs))
             for i in range(len(res) - 1))
    assert ok


def test_tokenweave_fuses_ar_add_norm():
    # smollm is non-SP dense: its layer graph has the ar->add->norm triple
    # (mamba's single ar sits at the layer-graph boundary — no target,
    # per DESIGN.md §Arch-applicability)
    plan, _ = plan_for("smollm-135m", "tokenweave")
    fused = [st for st in plan.steps if st.kind == "fused"]
    assert fused and all(st.replace_name == "tokenweave" for st in fused)
    assert all(len(st.handles) == 3 for st in fused)


def test_comet_fuses_dispatch_gemm_combine():
    plan, _ = plan_for("deepseek-moe-16b", "comet")
    fused = [st for st in plan.steps if st.kind == "fused"]
    assert len(fused) == 1 and fused[0].replace_name == "comet"


def test_flux_fuses_linear_allreduce():
    plan, _ = plan_for("smollm-135m", "flux")
    fused = [st for st in plan.steps if st.kind == "fused"]
    assert len(fused) >= 1 and fused[0].replace_name == "flux"


def test_dynamic_picks_by_context():
    dyn = get_strategy("dynamic", split_tokens=64, seq_tokens=8)
    cfg = get_smoke_config("deepseek-moe-16b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("train", B, S)
    seg = [x for x in segs if "layer" in x.name][-1]
    g = partition(seg.graph, dyn.partition_rules(), default_depth=2)

    from repro.core.scheduler import SchedCtx
    big = SchedCtx(g, ScheduleContext(local_batch=8, seq_len=512,
                                      phase="train"))
    assert dyn.pick(big).name == "dbo"
    small = SchedCtx(g, ScheduleContext(local_batch=1, seq_len=16,
                                        phase="decode"))
    assert dyn.pick(small).name == "sequential"
    mid = SchedCtx(g, ScheduleContext(local_batch=32, seq_len=1,
                                      phase="decode"))
    assert dyn.pick(mid).name == "sbo"


def test_loc_budget_matches_paper_table2():
    """Table 2 analogue: each strategy implementation stays within the
    same order of engineering cost the paper reports (~10-70 LoC)."""
    import inspect
    from repro.core.strategies import (comet, dbo, flux, nanoflow, sbo,
                                       tokenweave)
    for mod, cls in ((nanoflow, "NanoFlow"), (dbo, "DualBatchOverlap"),
                     (sbo, "SingleBatchOverlap"), (tokenweave, "TokenWeave"),
                     (comet, "Comet"), (flux, "Flux")):
        src = inspect.getsource(getattr(mod, cls))
        loc = len([l for l in src.splitlines()
                   if l.strip() and not l.strip().startswith(("#", '"'))])
        assert loc <= 80, (cls, loc)
