"""Paper Algorithm 1: static data-flow/memory analysis unit tests —
ref-count death sites, prealloc flags, and the zero-copy merge contract
(no ``concatenate`` on the merge path in the lowered HLO)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FULL, OpSchedulerBase, ScheduleContext, Realizer,
                        realize, record_plan, static_analysis, trace)
from repro.core.analysis import BUF
from repro.core.module import Module, Op, Param
from repro.core.plan import OpHandle


class Lin(Op):
    def __init__(self, d_in, d_out, name):
        super().__init__()
        self.w = Param((d_in, d_out), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return x @ p["w"]


class Chain(Module):
    def __init__(self, d=8, n=3):
        super().__init__()
        for i in range(n):
            setattr(self, f"l{i}", Lin(d, d, f"l{i}"))
        self.n = n

    def forward(self, x):
        for i in range(self.n):
            x = getattr(self, f"l{i}")(x)
        return x


class SplitThenMerge(OpSchedulerBase):
    """l0 per-micro-batch, l1 merged, l2 merged — forces a prealloc
    buffer between l0 (per-part) and l1 (FULL)."""

    def schedule(self, ctx):
        ctx.split([4, 4])
        g = ctx.graph
        oids = g.topo_order()
        ctx.execute(OpHandle(oids[0], 0, "l0"))
        ctx.execute(OpHandle(oids[0], 1, "l0"))
        ctx.execute(tuple(OpHandle(oids[1], i, "l1") for i in (0, 1)))
        ctx.execute(tuple(OpHandle(oids[2], i, "l2") for i in (0, 1)))


def setup():
    net = Chain()
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)})
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    return net, g, params, x


def test_prealloc_flag_on_merge_point():
    net, g, params, x = setup()
    plan = record_plan(g, SplitThenMerge(), ScheduleContext(local_batch=8))
    ana = static_analysis(g, plan)
    l0_out = g.nodes[g.topo_order()[0]].outputs[0]
    assert l0_out in ana.prealloc          # Alg.1 line 5
    # only the merge-point tensor gets a buffer
    assert len(ana.prealloc) == 1
    assert ana.buffer_bytes == 8 * 8 * 4


def test_death_sites_bound_liveness():
    net, g, params, x = setup()
    plan = record_plan(g, SplitThenMerge(), ScheduleContext(local_batch=8))
    ana = static_analysis(g, plan)
    oids = g.topo_order()
    l0_out = g.nodes[oids[0]].outputs[0]
    l1_out = g.nodes[oids[1]].outputs[0]
    # the merge buffer dies when l1 consumes it (step index 2)
    assert ana.death[(l0_out, BUF)] == 2
    # l1's merged output dies at l2 (step 3)
    assert ana.death[(l1_out, FULL)] == 3


def test_ref_counts_match_consumption():
    net, g, params, x = setup()
    plan = record_plan(g, SplitThenMerge(), ScheduleContext(local_batch=8))
    ana = static_analysis(g, plan)
    l0_out = g.nodes[g.topo_order()[0]].outputs[0]
    # consumed once, at FULL, via the assembled buffer
    assert ana.ref_count((l0_out, FULL)) == 1


def test_split_then_merge_correct():
    net, g, params, x = setup()
    want = net.apply(params, x)
    plan = record_plan(g, SplitThenMerge(), ScheduleContext(local_batch=8))
    got = realize(g, plan, params, {"x": x})["out"]
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_zero_copy_merge_no_concatenate_in_hlo():
    """The merge path must lower to dynamic-update-slice writes into the
    preallocated buffer — never a concatenate (the paper's zero-copy
    resharding claim, checked on the actual HLO)."""
    net, g, params, x = setup()
    plan = record_plan(g, SplitThenMerge(), ScheduleContext(local_batch=8))
    rz = Realizer(g, plan)

    def f(params, x):
        return rz(params, {"x": x})["out"]

    hlo = jax.jit(f).lower(params, x).as_text()
    assert "concatenate" not in hlo
    assert "dynamic-update-slice" in hlo or "dynamic_update_slice" in hlo


def test_gc_drops_env_references():
    """After realize, the env must not retain dead intermediates: we
    check the death table covers every produced tensor."""
    net, g, params, x = setup()
    plan = record_plan(g, SplitThenMerge(), ScheduleContext(local_batch=8))
    ana = static_analysis(g, plan)
    produced = {(t, p) for ws in ana.writes for (t, p) in ws}
    for key in produced:
        assert key in ana.death or key[0] in ana.prealloc
