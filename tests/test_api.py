"""PR-5 frontend tests: StrategyPolicy combinators, policy-salted
PlanStore keys, the repro.api.Program facade, and the deprecation shims
over the pre-facade entry points."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro._deprecation import reset as reset_deprecations
from repro.core import (LoweringError, PlanStore, Realizer, ScheduleContext,
                        by_phase, by_token_threshold, first_viable, has_ops,
                        local_batch_below, record_plan, resolve_strategy,
                        strategy_salt, trace, when)
from repro.core.module import Module, Op, Param
from repro.core.strategies import get_strategy
from repro.core.strategies.dynamic import dynamic_policy


# -- fixtures ----------------------------------------------------------------


class _Linear(Op):
    resource = "compute"

    def __init__(self, d, name):
        super().__init__()
        self.w = Param((d, d), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return jnp.tanh(x @ p["w"])


class _Net(Module):
    def __init__(self, d=8):
        super().__init__()
        self.lin0 = _Linear(d, "lin0")
        self.lin1 = _Linear(d, "lin1")
        self.lin2 = _Linear(d, "lin2")

    def forward(self, x):
        return self.lin2(self.lin1(self.lin0(x)))


def _ctx(phase="prefill", b=8, s=256):
    return ScheduleContext(local_batch=b, global_batch=b, seq_len=s,
                           phase=phase, arch="t")


# -- policy combinators ------------------------------------------------------


def test_by_phase_routes_and_defaults():
    p = by_phase(decode="sequential", default="sbo")
    assert type(p(_ctx("decode"))).__name__ == "Sequential"
    assert type(p(_ctx("prefill"))).__name__ == "SingleBatchOverlap"
    with pytest.raises(KeyError, match="no branch"):
        by_phase(decode="sequential")(_ctx("train"))


def test_by_token_threshold_orders():
    p = by_token_threshold([(64, "sequential"), (2048, "sbo")],
                           above="nanoflow")
    assert type(p(_ctx(b=1, s=8))).__name__ == "Sequential"
    assert type(p(_ctx(b=2, s=128))).__name__ == "SingleBatchOverlap"
    assert type(p(_ctx(b=8, s=1024))).__name__ == "NanoFlow"
    with pytest.raises(ValueError, match="ascend"):
        by_token_threshold([(2048, "sbo"), (64, "sequential")],
                           above="nanoflow")


def test_first_viable_and_when():
    p = first_viable(when(local_batch_below(2), "sequential"),
                     default="nanoflow")
    assert type(p(_ctx(b=1))).__name__ == "Sequential"
    assert type(p(_ctx(b=8))).__name__ == "NanoFlow"
    # a top-level decline is a loud error, not a silent None
    undecided = first_viable(when(local_batch_below(2), "sequential"))
    with pytest.raises(ValueError, match="declined"):
        resolve_strategy(undecided, _ctx(b=8))


def test_has_ops_reads_graph_from_context():
    net = _Net()
    g = trace(net, {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32)})
    pred = has_ops(r"lin1")
    assert not pred(_ctx())                       # no graph rode along
    assert resolve_strategy(
        first_viable(when(pred, "sbo"), default="sequential"),
        _ctx(), graph=g).name == "sbo"
    assert resolve_strategy(
        first_viable(when(has_ops(r"nope"), "sbo"), default="sequential"),
        _ctx(), graph=g).name == "sequential"


def test_dynamic_policy_matches_legacy_pick():
    """The combinator reimplementation preserves the PR-0 pick table."""
    p = dynamic_policy()
    assert type(p(_ctx(b=1, s=8))).__name__ == "Sequential"
    assert type(p(_ctx(b=4, s=100))).__name__ == "SingleBatchOverlap"
    assert type(p(_ctx(b=1, s=4096))).__name__ == "SingleBatchOverlap"
    assert type(p(_ctx(b=8, s=1024))).__name__ == "NanoFlow"
    assert type(p(_ctx("decode", b=4, s=1))).__name__ == "Sequential"
    # DynamicScheduler defers to the same policy at schedule time
    dyn = get_strategy("dynamic")
    assert dyn.identity()[0] == "dynamic"
    assert dyn.partition_rules() == p.partition_rules()


def test_strategy_salt_stability_and_separation():
    assert strategy_salt(get_strategy("dynamic")) == \
        strategy_salt(get_strategy("dynamic"))
    assert strategy_salt(get_strategy("dynamic")) != \
        strategy_salt(get_strategy("dynamic", split_tokens=512))
    assert strategy_salt(get_strategy("sequential")) != \
        strategy_salt(get_strategy("sbo"))
    assert strategy_salt(dynamic_policy()) == strategy_salt(dynamic_policy())
    # combinator structure enters the identity
    assert strategy_salt(by_phase(default="sequential")) != \
        strategy_salt(by_phase(decode="sequential", default="sequential"))


# -- policy-salted PlanStore keys (satellite) --------------------------------


def _lowered_via(store, policy, graph, info):
    from repro.core.plan import strategy_salt as salt_of
    sched = resolve_strategy(policy, info, graph=graph)
    plan = record_plan(graph, sched, info)
    return store.get_or_lower(graph, plan,
                              salt=f"t|{info.phase}|{salt_of(policy)}")


def test_two_policies_two_outer_keys_zero_cross_hits(tmp_path):
    """Same graph, same resolved scheduler, two policies: distinct outer
    keys, no cross-policy cache hits — and a restart redeems both."""
    net = _Net()
    g = trace(net, {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32)})
    info = _ctx(b=4, s=1)
    pol_a = repro.core.as_policy("sequential")
    pol_b = by_phase(default="sequential")     # resolves identically
    path = str(tmp_path / "pol.dfps")
    store = PlanStore(path=path)
    _lowered_via(store, pol_a, g, info)
    _lowered_via(store, pol_b, g, info)
    st = store.stats
    assert st["misses"] == 2, st               # B never hit A's entry
    assert st["hits"] == 0 and st["shares"] == 0, st
    assert len({outer for outer, _ in store._plans}) == 2
    # same policy again: a clean hit
    _lowered_via(store, pol_a, g, info)
    assert store.stats["hits"] == 1
    assert store.save() == 2

    store2 = PlanStore.open(path)
    _lowered_via(store2, pol_a, g, info)
    _lowered_via(store2, pol_b, g, info)
    st2 = store2.stats
    assert st2["restore_hits"] == 2, st2       # both policies redeemed
    assert st2["misses"] == 0, st2


def test_program_policy_swap_never_replays(tmp_path):
    """Facade-level version of the same contract: one store, two
    programs with different policies — zero cross hits."""
    net = _Net()
    ex = {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    store = PlanStore()
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    prog_a = repro.api.compile(net, policy="sequential",
                               example_inputs=ex, plan_store=store)
    prog_b = repro.api.compile(net, policy=by_phase(default="sequential"),
                               example_inputs=ex, plan_store=store)
    out_a = prog_a(params, {"x": x})
    out_b = prog_b(params, {"x": x})
    np.testing.assert_allclose(np.asarray(out_a["out"]),
                               np.asarray(out_b["out"]), atol=1e-6)
    st = store.stats
    assert st["misses"] == 2 and st["hits"] == 0, st


def test_policy_branch_rules_use_union_partition():
    """Two buckets resolving to different branches (one with partition
    rules, one without) must see the SAME partitioned graph — branch-
    dependent partitioning would diverge the structural keys and kill
    cross-bucket PlanStore sharing."""
    from repro.core import OpSchedulerBase, SplitFunc

    class RuledSeq(OpSchedulerBase):
        name = "ruledseq"

        def partition_rules(self):
            return [SplitFunc(r"lin1")]

    net = _Net()
    ex = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    policy = by_token_threshold([(6, "sequential")], above=RuledSeq())
    prog = repro.api.compile(net, policy=policy, example_inputs=ex)
    assert type(policy(_ctx(b=4, s=0))).__name__ == "Sequential"
    assert isinstance(policy(_ctx(b=8, s=0)), RuledSeq)
    prog.plan(local_batch=4)             # Sequential branch
    prog.plan(local_batch=8)             # RuledSeq branch
    st = prog.stats
    # identical partitioned structure: the second bucket is a pure hit
    assert st["misses"] == 1 and st["hits"] == 1, st


# -- specialize_rejects fallback coverage (satellite) ------------------------


def _graph_plan_bucket(net, b):
    g = trace(net, {"x": jax.ShapeDtypeStruct((b, 8), jnp.float32)})
    info = ScheduleContext(local_batch=b)
    plan = record_plan(g, get_strategy("sequential"), info)
    return g, plan


def test_specialize_reject_on_restored_skeleton(tmp_path, monkeypatch):
    """Restart path: when the rehydrated canonical skeleton cannot
    specialize an unseen bucket, the store counts the reject and falls
    back to a cold lower that still computes correctly."""
    from repro.core import plan_store as plan_store_mod
    net = _Net()
    path = str(tmp_path / "skel.dfps")
    store = PlanStore(path=path)
    g4, p4 = _graph_plan_bucket(net, 4)
    store.get_or_lower(g4, p4, salt="s")
    assert store.save() == 1

    store2 = PlanStore.open(path)

    def always_reject(*a, **k):
        raise LoweringError("forced drift")
    monkeypatch.setattr(plan_store_mod, "specialize", always_reject)
    g8, p8 = _graph_plan_bucket(net, 8)
    lowered = store2.get_or_lower(g8, p8, salt="s")
    st = store2.stats
    assert st["restore_canonicals"] == 1, st   # skeleton was rehydrated
    assert st["specialize_rejects"] == 1, st
    assert st["misses"] == 1, st               # cold-lower fallback
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    want = Realizer(g8, p8, lowered=False)(params, {"x": x})
    got = _realizer_with(g8, p8, lowered)(params, {"x": x})
    np.testing.assert_allclose(np.asarray(got["out"]),
                               np.asarray(want["out"]), atol=1e-6)


def _realizer_with(graph, plan, lowered):
    rz = Realizer.__new__(Realizer)
    rz.graph = graph
    rz.plan = plan
    rz._nodes = graph.nodes
    rz.lowered = lowered
    rz.analysis = lowered.analysis
    return rz


def test_specialize_reject_live_canonical_still_correct(monkeypatch):
    """Live-store reject (no restart): fallback result is bit-identical
    to the interpreter reference."""
    from repro.core import plan_store as plan_store_mod
    net = _Net()
    store = PlanStore()
    g4, p4 = _graph_plan_bucket(net, 4)
    store.get_or_lower(g4, p4, salt="s")

    def always_reject(*a, **k):
        raise LoweringError("forced drift")
    monkeypatch.setattr(plan_store_mod, "specialize", always_reject)
    g8, p8 = _graph_plan_bucket(net, 8)
    lowered = store.get_or_lower(g8, p8, salt="s")
    assert store.stats["specialize_rejects"] == 1
    assert store.stats["misses"] == 2
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    want = Realizer(g8, p8, lowered=False)(params, {"x": x})
    got = _realizer_with(g8, p8, lowered)(params, {"x": x})
    np.testing.assert_allclose(np.asarray(got["out"]),
                               np.asarray(want["out"]), atol=1e-6)


# -- the facade --------------------------------------------------------------


def test_program_graph_path_matches_sequential():
    net = _Net()
    ex = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    want = repro.api.compile(net, policy="sequential",
                             example_inputs=ex)(params, {"x": x})
    prog = repro.api.compile(net, policy="sbo", example_inputs=ex)
    plan = prog.plan(local_batch=8)
    assert plan.steps
    got = prog(params, {"x": x})
    np.testing.assert_allclose(np.asarray(got["out"]),
                               np.asarray(want["out"]), atol=1e-6)
    # second call is a pure cache hit (one realizer per shape bucket)
    prog(params, {"x": x})
    assert prog.stats["misses"] == 1


def test_program_train_step_smoke():
    prog = repro.api.compile("chatglm3-6b", smoke=True)
    step = prog.train_step(2, 16)
    assert step.init_opt is not None and step.segments
    params = prog.init_params(0, phase="train")
    opt = step.init_opt(params)
    B, S = 2, 16
    batch = {"ids": jnp.zeros((B, S), jnp.int32) + 3,
             "labels": jnp.zeros((B, S), jnp.int32) + 4,
             "positions": jnp.broadcast_to(
                 jnp.arange(S, dtype=jnp.int32), (B, S))}
    _, _, metrics = step(params, opt, batch, 0)
    assert np.isfinite(float(metrics["loss"]))


def test_program_requires_right_path():
    net = _Net()
    ex = {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    prog = repro.api.compile(net, example_inputs=ex)
    with pytest.raises(TypeError, match="raw Module"):
        prog.train_step(2, 16)
    lm = repro.api.compile("chatglm3-6b", smoke=True)
    with pytest.raises(TypeError, match="wraps an LM"):
        lm({}, {})
    with pytest.raises(ValueError, match="example_inputs"):
        repro.api.compile(net)


# -- deprecation shims -------------------------------------------------------


def test_old_builders_warn_once(monkeypatch):
    import repro.launch.steps as steps_mod
    import repro.train.step as train_mod
    sentinel = object()
    monkeypatch.setattr(train_mod, "_build_train_step",
                        lambda *a, **k: sentinel)
    monkeypatch.setattr(steps_mod, "_build_global_train_step",
                        lambda *a, **k: sentinel)
    reset_deprecations()
    with pytest.warns(DeprecationWarning, match="repro.api.compile"):
        assert train_mod.build_train_step(None, None, 2, 4, None) is sentinel
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second call: silent
        assert train_mod.build_train_step(None, None, 2, 4, None) is sentinel
    with pytest.warns(DeprecationWarning, match="mesh"):
        assert steps_mod.build_global_train_step(None, None, None, None) \
            is sentinel


def test_compile_cache_shims_warn_and_behave():
    from repro.core import compile_cache as legacy_mod
    from repro.core.plan_store import (GLOBAL_CACHE, GLOBAL_PLAN_CACHE,
                                       GLOBAL_STORE, CompileCache,
                                       LoweredPlanCache)
    assert GLOBAL_CACHE is GLOBAL_STORE
    assert GLOBAL_PLAN_CACHE is GLOBAL_STORE
    assert legacy_mod.CompileCache is CompileCache
    assert legacy_mod.GLOBAL_CACHE is GLOBAL_STORE
    reset_deprecations()
    with pytest.warns(DeprecationWarning, match="PlanStore"):
        cc = CompileCache(capacity=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # warn-once
        cc2 = CompileCache(capacity=2)
    fn = cc.get_or_build(("k", 1), lambda: (lambda x: x + 1))
    assert fn(1) == 2
    assert cc.get_or_build(("k", 1), lambda: (lambda x: x + 9))(1) == 2
    # legacy stats contract: exec counters mirrored onto the old keys
    assert cc.stats["hits"] == 1 and cc.stats["misses"] == 1
    assert len(cc) == cc.n_execs == 1
    del cc2
    reset_deprecations()
    with pytest.warns(DeprecationWarning, match="PlanStore"):
        lp = LoweredPlanCache(capacity=8)
    assert len(lp) == lp.n_plans == 0
    assert lp.plan_capacity == 8


# -- Program bundles (save/load one-file deployment) -------------------------

def test_program_bundle_round_trip(tmp_path):
    """save() packs arch + policy spec + cache backend + plans into one
    file; load() rebuilds the Program and replays without re-lowering."""
    from repro.serve import PagedCache
    path = str(tmp_path / "prog.dfpb")
    p1 = repro.api.compile("chatglm3-6b", policy="sequential", smoke=True,
                           cache="paged")
    p1.prefill(global_batch=1, seq_len=16)
    n = p1.save(path)
    assert n > 0
    misses1 = p1.stats["misses"]
    assert misses1 > 0

    p2 = repro.api.Program.load(path)
    assert isinstance(p2.cache_backend, PagedCache)
    assert p2.policy_spec == "sequential"
    assert p2.model.cfg.name == p1.model.cfg.name
    p2.prefill(global_batch=1, seq_len=16)
    assert p2.stats["misses"] == 0, \
        f"loaded program re-lowered: {p2.stats}"


def test_program_bundle_rejects_bad_header(tmp_path):
    import json

    from repro.api import ProgramBundleError
    path = str(tmp_path / "prog.dfpb")
    p1 = repro.api.compile("chatglm3-6b", policy="sequential", smoke=True)
    p1.prefill(global_batch=1, seq_len=16)
    p1.save(path)

    with open(path) as f:
        lines = f.read().splitlines(True)
    hdr = json.loads(lines[0])
    hdr["format_version"] += 1
    bad = str(tmp_path / "bad.dfpb")
    with open(bad, "w") as f:
        f.writelines([json.dumps(hdr) + "\n"] + lines[1:])
    with pytest.raises(ProgramBundleError, match="format"):
        repro.api.Program.load(bad)

    junk = str(tmp_path / "junk.dfpb")
    with open(junk, "w") as f:
        f.write("not a bundle\n")
    with pytest.raises(ProgramBundleError):
        repro.api.Program.load(junk)


def test_program_bundle_opaque_policy(tmp_path):
    """An opaque policy object can't ride in the bundle: load() demands
    an explicit policy= and trusts it (no salt check); a named policy
    needs nothing."""
    from repro.api import ProgramBundleError
    path = str(tmp_path / "prog.dfpb")
    p1 = repro.api.compile("chatglm3-6b",
                           policy=get_strategy("sequential"), smoke=True)
    p1.prefill(global_batch=1, seq_len=16)
    p1.save(path)
    with pytest.raises(ProgramBundleError, match="policy"):
        repro.api.Program.load(path)
    p2 = repro.api.Program.load(path, policy="sequential")
    p2.prefill(global_batch=1, seq_len=16)
    assert p2.stats["misses"] == 0
