"""Paper Tables 1-2 analogue: engineering cost in lines of code.

Table 2: LoC of each strategy implementation, split into partition rules
and scheduler logic.  Table 1: LoC the model definitions needed to become
DynaFlow-schedulable (the `mark(...)` annotations + Op subclassing deltas,
counted as annotation call sites — the framework integration itself is
the core library, shared by every model).
"""
import inspect
import re


def _loc(src: str) -> int:
    return len([l for l in src.splitlines()
                if l.strip() and not l.strip().startswith(("#", '"', "'"))])


def strategy_rows():
    from repro.core.strategies import (comet, dbo, flux, nanoflow, sbo,
                                       tokenweave)
    rows = []
    for mod, cls, label in ((nanoflow, "NanoFlow", "NanoFlow (split)"),
                            (dbo, "DualBatchOverlap", "DBO (split)"),
                            (sbo, "SingleBatchOverlap", "SBO (overlap)"),
                            (tokenweave, "TokenWeave", "TokenWeave (fuse)"),
                            (comet, "Comet", "Comet (fuse)"),
                            (flux, "Flux", "Flux (fuse)")):
        c = getattr(mod, cls)
        part = _loc(inspect.getsource(c.partition_rules)) \
            if "partition_rules" in c.__dict__ else 0
        helpers = sum(
            _loc(inspect.getsource(getattr(c, m)))
            for m in ("triples", "chains", "pairs") if m in c.__dict__)
        sched = _loc(inspect.getsource(c.schedule)) + helpers
        rows.append((label, part, sched))
    return rows


def annotation_rows():
    """Per-model annotation cost: `mark(` call sites + schedulable-Op
    declarations beyond plain jnp code (Table 1 'Model' column spirit)."""
    import repro.models.moe as moe
    import repro.models.base as base
    import repro.models.mamba2 as mamba
    rows = []
    for mod, label in ((base, "dense layer"), (moe, "moe layer"),
                       (mamba, "mamba2 layer")):
        src = inspect.getsource(mod)
        marks = len(re.findall(r"with mark\(", src))
        rows.append((label, marks))
    return rows


def run():
    out = []
    for label, part, sched in strategy_rows():
        out.append(f"loc/{label},partition={part},scheduler={sched}")
    for label, marks in annotation_rows():
        out.append(f"annotations/{label},mark_sites={marks},")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
