"""Paper Tables 1-2 analogue: engineering cost in lines of code.

Table 2: LoC of each strategy implementation, split into partition rules
and scheduler logic.  Table 1: LoC the model definitions needed to become
DynaFlow-schedulable (the `mark(...)` annotations + Op subclassing deltas,
counted as annotation call sites — the framework integration itself is
the core library, shared by every model).

Since PR 5 the integration-cost claim is *enforceable*: every example
driver's LoC is measured against a checked-in budget
(``benchmarks/loc_budget.csv``) and CI's ``loc-gate`` job fails when an
example regresses past it — if the facade ever stops being a facade, the
gate says so.  ``--check`` also asserts the flagship examples go through
``repro.api.compile`` with none of the pre-facade entry points.
"""
import inspect
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = ("examples/quickstart.py", "examples/serve_batched.py",
            "examples/custom_strategy.py", "examples/train_ft.py")
# pre-facade entry points the flagship examples must not touch
# (DynamicScheduler( is the PR-8 deprecation: spell it policy="dynamic")
BANNED = ("record_plan(", "build_global_", "PlanStore.open(",
          "build_train_step(", "DynamicScheduler(")
FACADE_ONLY = ("examples/quickstart.py", "examples/serve_batched.py",
               "src/repro/launch/dryrun.py", "src/repro/launch/serve.py")


def _loc(src: str) -> int:
    return len([l for l in src.splitlines()
                if l.strip() and not l.strip().startswith(("#", '"', "'"))])


def strategy_rows():
    from repro.core.strategies import (comet, dbo, flux, nanoflow, sbo,
                                       tokenweave)
    rows = []
    for mod, cls, label in ((nanoflow, "NanoFlow", "NanoFlow (split)"),
                            (dbo, "DualBatchOverlap", "DBO (split)"),
                            (sbo, "SingleBatchOverlap", "SBO (overlap)"),
                            (tokenweave, "TokenWeave", "TokenWeave (fuse)"),
                            (comet, "Comet", "Comet (fuse)"),
                            (flux, "Flux", "Flux (fuse)")):
        c = getattr(mod, cls)
        part = _loc(inspect.getsource(c.partition_rules)) \
            if "partition_rules" in c.__dict__ else 0
        helpers = sum(
            _loc(inspect.getsource(getattr(c, m)))
            for m in ("triples", "chains", "pairs") if m in c.__dict__)
        sched = _loc(inspect.getsource(c.schedule)) + helpers
        rows.append((label, part, sched))
    return rows


def annotation_rows():
    """Per-model annotation cost: `mark(` call sites + schedulable-Op
    declarations beyond plain jnp code (Table 1 'Model' column spirit)."""
    import repro.models.moe as moe
    import repro.models.base as base
    import repro.models.mamba2 as mamba
    rows = []
    for mod, label in ((base, "dense layer"), (moe, "moe layer"),
                       (mamba, "mamba2 layer")):
        src = inspect.getsource(mod)
        marks = len(re.findall(r"with mark\(", src))
        rows.append((label, marks))
    return rows


def example_rows():
    """Integration LoC of each example driver — what a user writes to go
    from model to scheduled execution, demo scaffolding included."""
    rows = []
    for rel in EXAMPLES:
        with open(os.path.join(REPO, rel)) as f:
            rows.append((rel, _loc(f.read())))
    return rows


def read_budget(path):
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            rel, budget = line.split(",")
            out[rel] = int(budget)
    return out


def check(budget_path) -> int:
    """Gate: every example within its LoC budget, flagship examples
    facade-only.  Returns a shell exit code."""
    budget = read_budget(budget_path)
    failures = []
    for rel, loc in example_rows():
        cap = budget.get(rel)
        if cap is None:
            failures.append(f"{rel}: no budget entry in {budget_path}")
        elif loc > cap:
            failures.append(
                f"{rel}: {loc} LoC exceeds budget {cap} — the facade "
                "stopped covering this workflow (or raise the budget "
                "with justification)")
        else:
            print(f"loc-gate OK {rel}: {loc} <= {cap}")
    for rel in FACADE_ONLY:
        with open(os.path.join(REPO, rel)) as f:
            src = f.read()
        hits = [b for b in BANNED if b in src]
        if hits:
            failures.append(
                f"{rel}: uses pre-facade entry points {hits}; route "
                "through repro.api.compile")
        elif "api.compile(" not in src:
            failures.append(f"{rel}: does not call repro.api.compile")
        else:
            print(f"loc-gate OK {rel}: facade-only")
    for msg in failures:
        print(f"loc-gate FAIL {msg}")
    return 1 if failures else 0


def run():
    out = []
    for label, part, sched in strategy_rows():
        out.append(f"loc/{label},partition={part},scheduler={sched}")
    for label, marks in annotation_rows():
        out.append(f"annotations/{label},mark_sites={marks},")
    for rel, loc in example_rows():
        out.append(f"integration/{rel},loc={loc},")
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--check":
        path = sys.argv[2] if len(sys.argv) > 2 else os.path.join(
            REPO, "benchmarks", "loc_budget.csv")
        sys.exit(check(path))
    print("\n".join(run()))
