"""Paper Fig. 14 analogue: ablation of the backend mechanisms.

  memory   — zero-copy prealloc merge vs a concatenate-based merge
             (bytes on the merge path)
  graph    — compile-cache (CUDA-graph analogue) on/off dispatch time
  dynamic  — dynamic per-context scheduling vs static always-split
             (modeled step time on a small-batch bucket)
"""
import time

import jax
import jax.numpy as jnp


def run():
    from repro.configs import get_smoke_config
    from repro.core import (Realizer, record_plan, static_analysis)
    from repro.core.scheduler import ScheduleContext
    from repro.core.strategies import get_strategy
    from repro.models.base import build_forward
    from repro.models.layers import MeshInfo
    from repro.models.registry import build_model
    from repro.roofline.overlap import plan_overlap, split_weight_penalty

    out = []
    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    B, S = 8, 32
    segs, binputs = model.build_segments("train", B, S)
    seg = [s for s in segs if s.count > 1][0]
    info = ScheduleContext(local_batch=B, seq_len=S, phase="train",
                           arch=cfg.name)

    # -- memory: zero-copy merge buffers vs concatenate ---------------------
    plan = record_plan(seg.graph, get_strategy("nanoflow", min_tokens=1),
                       info)
    ana = static_analysis(seg.graph, plan)
    # a concatenate-based merge copies every per-part tensor once more
    concat_bytes = 2 * ana.buffer_bytes
    out.append(f"ablation/zero_copy_buffer_bytes,{ana.buffer_bytes},B")
    out.append(f"ablation/concat_merge_bytes,{concat_bytes},B")

    # -- graph: compiled dispatch vs eager re-trace -------------------------
    fwd = build_forward(segs, get_strategy("sequential"), info)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    batch = {"ids": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                           (B, S))}
    jf = jax.jit(lambda p, b: fwd(p, b)["loss_sum"])
    jf(params, batch).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        jf(params, batch).block_until_ready()
    t_cached = (time.perf_counter() - t0) / 10 * 1e6
    t0 = time.perf_counter()
    with jax.disable_jit():
        fwd(params, batch)
    t_eager = (time.perf_counter() - t0) * 1e6
    out.append(f"ablation/dispatch_compiled,{t_cached:.0f},us")
    out.append(f"ablation/dispatch_eager,{t_eager:.0f},us")
    out.append(f"ablation/graph_speedup,{t_eager / max(t_cached, 1):.1f},x")

    # -- dynamic vs static splitting on a small bucket -----------------------
    cfg_full = __import__("repro.configs", fromlist=["get_config"]) \
        .get_config("chatglm3-6b")
    m16 = build_model(cfg_full, MeshInfo(tp=16, dp=16, attn_impl="chunked"))
    segs16, _ = m16.build_segments("train", 2, 256)   # small bucket
    seg16 = [s for s in segs16 if s.count > 1][0]
    info16 = ScheduleContext(local_batch=2, seq_len=256, phase="train",
                             arch=cfg_full.name)
    static_split = record_plan(seg16.graph,
                               get_strategy("nanoflow", min_tokens=1),
                               info16)
    pen = split_weight_penalty(seg16.graph, static_split.num_mb)
    t_static = plan_overlap(seg16.graph, static_split,
                            extra_weight_read_bytes=pen).t_overlapped
    dynamic = record_plan(seg16.graph, get_strategy("dynamic"), info16)
    pen_d = split_weight_penalty(seg16.graph, dynamic.num_mb)
    t_dyn = plan_overlap(seg16.graph, dynamic,
                         extra_weight_read_bytes=pen_d).t_overlapped
    out.append(f"ablation/smallbatch_static_split,{t_static*1e6:.1f},us_modeled")
    out.append(f"ablation/smallbatch_dynamic,{t_dyn*1e6:.1f},us_modeled")
    out.append(f"ablation/dynamic_over_static,{t_static/max(t_dyn,1e-12):.3f},x")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
