"""Paper Fig. 8 analogue: scheduling-overhead microbenchmark.

GPU DynaFlow measures CPU launch time per forward; the JAX analogue
decomposes the dispatch path into (a) plan construction (the Python
scheduler), (b) static analysis (Alg. 1), (c) plan lowering to the
slot-based instruction stream, (d) trace+realize build — interpreted
vs lowered-with-replay, the cost every re-jit pays — and (e) compile-
cache-hit dispatch, mirroring CUDA-graph replay.  Also reproduces the
fallback point: sequential-mode planning is cheaper than dynamic.

Key rows:
  overhead/build_interpreted   analysis + interpreter build + full trace
  overhead/build_lowered       warm plan-cache hit + capture replay trace
  overhead/build_speedup       the paper's capture-vs-interpret claim
  overhead/multibucket_*       PlanStore cross-bucket warm-up: the first
                               prefill bucket pays the full lowering, every
                               later bucket specializes the canonical one
  overhead/planstore_share_rate  fraction of cold bucket warm-ups served
                               by specialization (CI gates this > 0)
  overhead/warmstart_*         persistent-store restart: a cold process
                               pays lower+specialize per bucket; a warm
                               process restores the serialized canonical
                               lowerings (CI gates speedup >= 2x and, in
                               the warmstart-gate job, restore misses == 0
                               across two separate processes)
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp


def _time(fn, n=20, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6        # us


def run(plan_store_path=None, with_serve=False):
    from repro.configs import get_smoke_config
    from repro.core import (PlanStore, Realizer, lower, partition,
                            record_plan, static_analysis)
    from repro.core.scheduler import ScheduleContext
    from repro.core.strategies import get_strategy
    from repro.models.layers import MeshInfo
    from repro.models.registry import build_model

    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    B, S = 4, 32
    segs, binputs = model.build_segments("train", B, S)
    seg = [s for s in segs if s.count > 1][0]
    info = ScheduleContext(local_batch=B, seq_len=S, phase="train",
                           arch=cfg.name)
    out = []

    for name in ("sequential", "dynamic", "nanoflow", "dbo"):
        strat = get_strategy(name) if name not in ("nanoflow", "dbo") \
            else get_strategy(name, min_tokens=1)
        g = seg.graph
        rules = strat.partition_rules()
        if rules:
            g = partition(g, rules, default_depth=2)
        t_plan = _time(lambda: record_plan(g, strat, info))
        plan = record_plan(g, strat, info)
        t_ana = _time(lambda: static_analysis(g, plan))
        t_low = _time(lambda: lower(g, plan))
        out.append(f"overhead/plan_{name},{t_plan:.1f},us")
        out.append(f"overhead/analysis_{name},{t_ana:.1f},us")
        out.append(f"overhead/lower_{name},{t_low:.1f},us")

    # -- interpreted vs lowered trace+realize build ------------------------
    # the cost of going from (graph, plan) to a traced computation, i.e.
    # what every fresh jit of a bucket pays per segment
    g = seg.graph
    plan = record_plan(g, get_strategy("sequential"), info)
    lay_params = seg.module.init(jax.random.PRNGKey(0))
    seg_inputs = {k: jnp.zeros(g.tensors[t].shape, g.tensors[t].dtype)
                  for k, t in g.inputs.items()}
    plan_cache = PlanStore()
    plan_cache.get_or_lower(g, plan)                     # warm, as in serving

    def build_interpreted():
        rz = Realizer(g, plan, lowered=False)            # runs Alg. 1 anew
        jax.make_jaxpr(lambda p, i: rz(p, i))(lay_params, seg_inputs)

    def build_lowered():
        rz = Realizer(g, plan, plan_cache=plan_cache)    # fingerprint hit
        jax.make_jaxpr(lambda p, i: rz(p, i))(lay_params, seg_inputs)

    build_lowered()                                      # capture once
    t_int = _time(build_interpreted, n=10)
    t_lowd = _time(build_lowered, n=10)
    out.append(f"overhead/build_interpreted,{t_int:.1f},us")
    out.append(f"overhead/build_lowered,{t_lowd:.1f},us")
    out.append(f"overhead/build_speedup,{t_int / max(t_lowd, 1e-9):.1f},x")

    # plan-to-dispatch latency: scheduler run included (cold plan, warm
    # lowering/capture — the serving steady state for a known bucket)
    def p2d_interpreted():
        p = record_plan(g, get_strategy("sequential"), info)
        rz = Realizer(g, p, lowered=False)
        jax.make_jaxpr(lambda pp, i: rz(pp, i))(lay_params, seg_inputs)

    def p2d_lowered():
        p = record_plan(g, get_strategy("sequential"), info)
        rz = Realizer(g, p, plan_cache=plan_cache)
        jax.make_jaxpr(lambda pp, i: rz(pp, i))(lay_params, seg_inputs)

    t_pi = _time(p2d_interpreted, n=10)
    t_pl = _time(p2d_lowered, n=10)
    out.append(f"overhead/plan_to_dispatch_interpreted,{t_pi:.1f},us")
    out.append(f"overhead/plan_to_dispatch_lowered,{t_pl:.1f},us")

    # -- multi-bucket warm-up: lowering cost paid once, not once/bucket --
    # Prefill buckets re-trace structurally identical layer programs at
    # different sequence lengths.  The PlanStore lowers the first bucket
    # (fingerprint-v2 miss: Alg. 1 + slot allocation) and serves every
    # later bucket by specializing that canonical lowering.
    buckets = (16, 32, 64)
    bucket_pairs = []
    for b in buckets:
        psegs, _ = model.build_segments("prefill", 1, b, s_max=128)
        pseg = [s for s in psegs if s.count > 1][0]
        pinfo = ScheduleContext(local_batch=1, seq_len=b, phase="prefill",
                                arch=cfg.name)
        pplan = record_plan(pseg.graph, get_strategy("dynamic"), pinfo)
        bucket_pairs.append((pseg.graph, pplan))
    op_cfg = model.op_closure_config()

    def warm_first():                    # full lower, fresh store each time
        PlanStore().get_or_lower(*bucket_pairs[0], salt="prefill",
                                 op_config=op_cfg)

    def warm_rest():                     # buckets 2..N: specialize path
        store = PlanStore()
        store.get_or_lower(*bucket_pairs[0], salt="prefill",
                           op_config=op_cfg)
        t0 = time.perf_counter()
        for gb, pb in bucket_pairs[1:]:
            store.get_or_lower(gb, pb, salt="prefill", op_config=op_cfg)
        dt = (time.perf_counter() - t0) / (len(buckets) - 1)
        assert store.stats["shares"] == len(buckets) - 1, store.stats
        return dt

    # best-of-k: these are ~100us one-shot paths, where mean-of-k soaks
    # up allocator/GC noise that the steady-state serving path never sees
    warm_first()
    t_first = min(_time(warm_first, n=5) for _ in range(8))
    t_shared = min(warm_rest() for _ in range(40)) * 1e6
    out.append(f"overhead/multibucket_warmup_first,{t_first:.1f},us")
    out.append(f"overhead/multibucket_warmup_shared,{t_shared:.1f},us")
    out.append(f"overhead/multibucket_share_speedup,"
               f"{t_first / max(t_shared, 1e-9):.1f},x")

    # end-to-end share rate over one store warming all buckets
    store = PlanStore()
    for gb, pb in bucket_pairs:
        store.get_or_lower(gb, pb, salt="prefill", op_config=op_cfg)
    out.append(f"overhead/planstore_share_rate,{store.share_rate:.3f},ratio")

    # -- persistent warm-start: restart cost with / without the artifact --
    # The gated pair isolates exactly the work persistence replaces: a
    # cold process runs Alg. 1 + slot allocation + instruction emission
    # (``lower``) per canonical entry; a warm process parses the entry
    # and rebinds callables (``rehydrate``).  Both sides pay plan
    # fingerprinting on a fresh ExecutionPlan, as a real restart does.
    from repro.core.plan import ExecutionPlan, structural_key
    from repro.core.plan_serde import parse_payload, rehydrate

    spath = os.path.join(tempfile.mkdtemp(prefix="dynaflow-bench-"),
                         "plan_store.dfps")
    g0, p0 = bucket_pairs[0]
    skey0 = structural_key(g0, p0)
    seed = PlanStore()
    for gb, pb in bucket_pairs:
        seed.get_or_lower(gb, pb, salt="prefill", op_config=op_cfg)
    seed.save(spath)
    with open(spath, encoding="utf-8") as f:
        payload = f.read().splitlines()[1].split(" ", 4)[4]

    def fresh_plan():
        return ExecutionPlan(steps=p0.steps, split_sizes=p0.split_sizes,
                             graph_fingerprint=p0.graph_fingerprint)

    def cold_lower():
        lower(g0, fresh_plan())

    def warm_restore():
        entry = parse_payload(payload)
        rehydrate(entry["buckets"][0], entry["analysis"], g0, fresh_plan(),
                  skey0)

    # interleaved best-of rounds: a transient load spike (CI neighbors)
    # lands on adjacent rounds of *both* sides instead of biasing one
    cold_rounds, warm_rounds = [], []
    for _ in range(10):
        cold_rounds.append(_time(cold_lower, n=10))
        warm_rounds.append(_time(warm_restore, n=10))
    t_coldp, t_warmp = min(cold_rounds), min(warm_rounds)
    out.append(f"overhead/coldstart_lower,{t_coldp:.1f},us")
    out.append(f"overhead/warmstart_restore,{t_warmp:.1f},us")
    out.append(f"overhead/warmstart_speedup,"
               f"{t_coldp / max(t_warmp, 1e-9):.1f},x")

    # end-to-end store work per restart (canonical restore + derived
    # buckets re-specialized on both sides; file open reported apart)
    def cold_start():
        s = PlanStore()
        for gb, pb in bucket_pairs:
            s.get_or_lower(gb, pb, salt="prefill", op_config=op_cfg)
        return s

    def warm_serve():
        s = PlanStore.open(spath)
        t0 = time.perf_counter()
        for gb, pb in bucket_pairs:
            s.get_or_lower(gb, pb, salt="prefill", op_config=op_cfg)
        return time.perf_counter() - t0, s

    t_cold = min(_time(cold_start, n=5) for _ in range(8))
    t_warm = min(warm_serve()[0] for _ in range(40)) * 1e6
    t_open = _time(lambda: PlanStore.open(spath), n=10)
    ws = warm_serve()[1]
    served = (ws.stats["restore_hits"] + ws.stats["shares"]
              + ws.stats["hits"] + ws.stats["misses"])
    out.append(f"overhead/coldstart_all_buckets,{t_cold:.1f},us")
    out.append(f"overhead/warmstart_all_buckets,{t_warm:.1f},us")
    out.append(f"overhead/warmstart_open,{t_open:.1f},us")
    out.append(f"overhead/restore_miss_rate,"
               f"{ws.stats['misses'] / max(served, 1):.3f},ratio")

    # cross-process gate: with --plan-store, a *previous invocation's*
    # artifact serves this process's buckets; the warmstart-gate CI job
    # runs the benchmark twice and asserts zero restore misses here.
    if plan_store_path:
        if os.path.exists(plan_store_path):
            xs = PlanStore.open(plan_store_path)
            for gb, pb in bucket_pairs:
                xs.get_or_lower(gb, pb, salt="prefill", op_config=op_cfg)
            out.append(f"overhead/warmstart_restore_misses,"
                       f"{xs.stats['misses']},count")
            out.append(f"overhead/warmstart_restore_hits,"
                       f"{xs.stats['restore_hits']},count")
            xs.save(plan_store_path)
        else:
            cold_start().save(plan_store_path)

    # compiled dispatch: cache hit vs miss (CUDA-graph replay analogue)
    from repro.models.base import build_forward
    cache = PlanStore()
    fwd = build_forward(segs, get_strategy("sequential"), info)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    batch = {"ids": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                           (B, S))}

    def build():
        return jax.jit(lambda p, b: fwd(p, b)["loss_sum"])

    t0 = time.perf_counter()
    fn = cache.get_or_build(("step", B, S), build)
    fn(params, batch).block_until_ready()
    t_miss = (time.perf_counter() - t0) * 1e6
    t_hit = _time(lambda: cache.get_or_build(("step", B, S), build)(
        params, batch).block_until_ready(), n=10)
    out.append(f"overhead/dispatch_cold,{t_miss:.1f},us")
    out.append(f"overhead/dispatch_cached,{t_hit:.1f},us")
    out.append(f"overhead/cache_speedup,{t_miss / max(t_hit, 1e-9):.1f},x")

    # -- serve-runtime summary: tiered async engine vs fixed-batch -------
    # baseline (the full per-tier breakdown lives in serve_bench.py; the
    # headline speedup and the tier share counters ride along here so
    # one overhead.csv carries the whole dispatch-path story).  Opt-in:
    # the serve trace costs a minute, so only the jobs that publish
    # overhead.csv pass --with-serve; the timed warmstart-gate runs and
    # the benchmarks/run.py table skip it.
    if with_serve:
        try:
            from benchmarks import serve_bench   # package harness path
        except ImportError:
            import serve_bench                   # script path
        srows = {r.split(",")[0]: r for r in serve_bench.run(requests=8,
                                                             repeats=2)}
        for key in ("serve/baseline_tps", "serve/tiered_tps",
                    "serve/tiered_speedup", "serve/decode_tier_shares",
                    "serve/decode_tier_lowers",
                    "serve/tiered_syncs_per_decode"):
            out.append(srows[key].replace("serve/", "overhead/serve_", 1))
        # speculative-decode summary on the repeat-heavy greedy workload
        sprows = {r.split(",")[0]: r
                  for r in serve_bench.run(repeats=2, spec="ngram")}
        for key in ("serve/spec_plain_tps", "serve/spec_accepted_tps",
                    "serve/spec_speedup", "serve/spec_acceptance_rate",
                    "serve/spec_syncs_per_decode",
                    "serve/spec_verify_lowers"):
            out.append(sprows[key].replace("serve/", "overhead/serve_", 1))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan-store", default=None,
                    help="persist the PlanStore here across invocations "
                         "(the CI warmstart-gate runs this twice)")
    ap.add_argument("--with-serve", action="store_true",
                    help="append the serve_bench summary rows "
                         "(tiered-vs-baseline tok/s + tier counters)")
    args = ap.parse_args()
    print("\n".join(run(plan_store_path=args.plan_store,
                        with_serve=args.with_serve)))
