"""Serve-runtime benchmark: tiered async engine vs fixed-batch baseline.

Measures the three tentpole mechanisms of the tiered serve runtime on a
mixed-load trace (partially occupied batch, decaying occupancy tail):

  * decode batch tiers — steps run at the smallest covering tier, and
    tiers 2..N specialize one canonical capture (share counters),
  * batched/chunked prefill — admission packs waiting requests into one
    bucketed call; prompts longer than the largest bucket chunk through
    the decode graph,
  * async host loop — on-device sampling + double buffering, at most one
    small host sync per decode iteration.

The baseline is the same engine configured back into the pre-tiered
shape: ``decode_tiers=(max_batch,)``, ``prefill_batch=1``,
``async_host=False`` — fixed-batch decode, one-request prefill, a host
sync per step.

Rows (name,value,unit):
  serve/baseline_tps, serve/tiered_tps, serve/tiered_speedup
  serve/{baseline,tiered}_ttft_p50_ms
  serve/decode_tier_shares     plan-level shares paid building tiers 2..N
  serve/decode_tier_lowers     cold lowers beyond the canonical tier (0)
  serve/tier_steps_<t>         decode steps run at tier t
  serve/{tiered,baseline}_syncs_per_decode   host syncs per decode step
  serve/chunk_steps            chunked-prefill steps in the trace

With ``--cache paged`` every engine runs on the paged KV backend
(``PagedCache``, page_size=16) and an extra equal-pool-bytes admission
comparison runs: a dense engine with 4 rows x 128 tokens vs a paged
engine with the same 512-token pool split into 32 pages across 16 rows.
Short requests then pack the paged pool far denser.  Extra rows:
  serve/dense_admitted         peak concurrent rows, dense pool
  serve/paged_admitted         peak concurrent rows, paged pool
  serve/paged_admitted_delta   paged - dense (gate: > 0, paged >= 2x)
  serve/paged_kv_util          peak page utilisation of the paged pool

With ``--inject`` an additional degraded-mode trace runs the tiered
engine under the chaos harness (allocation denials, a poisoned request,
a straggler iteration, a memory-pressure window) with priorities and
deadlines on the trace, and asserts the lifecycle invariants: every
non-shed request terminates as Finished or Failed, zero KV rows leak,
and nothing is stranded.  Extra rows:
  serve/degraded_tps
  serve/injected_{shed,preempted,failed,deadline_missed}
  serve/injected_stranded      must be 0

With ``--spec {ngram,self}`` the bench instead runs the speculative
multi-token decode comparison on a repeat-heavy greedy workload (long
generations whose token streams fall into near-periodic tails — the
draft-friendly regime speculative decoding targets): a plain greedy
engine vs the same engine with ``SpecConfig(proposer=..., k=...)``.
The spec engine is measured through a PlanStore save/load restart so
its verify buckets must restore warm (zero ``lower()`` calls), and the
spec outputs are asserted bitwise-identical to the plain outputs.
Rows:
  serve/spec_plain_tps         plain greedy decode throughput
  serve/spec_accepted_tps      spec engine emitted-tokens/s
  serve/spec_speedup           accepted_tps / plain_tps
  serve/spec_acceptance_rate   accepted drafts / drafted tokens
  serve/spec_rollbacks         verify steps that rolled cache_len back
  serve/spec_fallbacks         iterations that fell back to plain decode
  serve/spec_syncs_per_decode  host syncs per decode iteration
  serve/spec_verify_lowers     lower() calls for verify buckets on the
                               warm store (must be 0)
  serve/spec_draft_k           the draft length used
"""
import argparse
import time

import numpy as np


def _trace(cfg, rng, requests, max_new):
    """Mixed load: short interactive requests plus a long-output tail so
    occupancy decays through the tiers, and one chunk-length prompt."""
    from repro.serve import Request
    out = []
    for i in range(requests):
        if i == requests - 1:
            n = 40                      # > largest bucket: chunked prefill
        else:
            n = int(rng.integers(4, 30))
        mn = max_new * 4 if i >= requests - 2 else max_new
        out.append(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, n,
                                               dtype=np.int32),
                           max_new_tokens=mn))
    return out


def _run_engine(eng, reqs):
    done0 = len(eng.finished)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run()[done0:]
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    ttft = [r.first_token_s - r.submitted_s for r in done]
    return dict(tps=toks / dt, toks=toks, dt=dt,
                ttft_p50_ms=float(np.percentile(ttft, 50)) * 1e3)


def _degraded_rows(engine_fn, cfg, requests, max_new):
    """Run the tiered engine under injected faults and assert the
    request-lifecycle invariants hold while degraded."""
    from repro.serve import (
        BoundedQueue,
        Failed,
        FaultInjector,
        Finished,
        Shed,
    )
    faults = FaultInjector(alloc_fail=(1, 4), poison={3: "decode"},
                           slow_iters=(2,), slow_s=0.01,
                           pressure=((5, 8, 4),))
    eng = engine_fn(faults=faults, admission=BoundedQueue(2 * requests))
    eng.warmup()
    rng = np.random.default_rng(7)
    reqs = _trace(cfg, rng, requests, max_new)
    for i, r in enumerate(reqs):
        r.priority = int(rng.integers(0, 3))
        if i % 3 == 0:
            r.deadline_s = 60.0
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    dt = time.perf_counter() - t0
    st = eng.stats

    # hard invariants: the degraded run is only reportable if the
    # engine survived it cleanly
    assert len(done) == len(reqs), "a request went missing"
    for r in done:
        assert isinstance(r.result, (Finished, Shed, Failed)), r
    assert st["submitted"] == st["finished"] + st["shed"] + st["failed"]
    assert len(eng.cache.free_rows) == eng.cfg.max_batch, "leaked KV rows"
    assert eng.cache.row_owner == {}, "leaked KV rows"
    assert st["stranded"] == 0, "degraded run stranded work"
    ok_toks = sum(len(r.output) for r in done if r.ok)
    return [
        f"serve/degraded_tps,{ok_toks / dt:.1f},tok/s",
        f"serve/injected_shed,{st['shed']},count",
        f"serve/injected_preempted,{st['preempted']},count",
        f"serve/injected_failed,{st['failed']},count",
        f"serve/injected_deadline_missed,{st['deadline_missed']},count",
        f"serve/injected_stranded,{st['stranded']},count",
    ]


def _admission_rows(model, params, strategy, cfg):
    """Equal-pool-bytes admission comparison: 4 dense rows x 128 tokens
    vs the same 512-token pool paged into 32 x 16-token pages across 16
    rows.  Short requests pack the paged pool far denser."""
    from repro.core.strategies import get_strategy
    from repro.serve import PagedCache, Request, ServeConfig, ServeEngine

    def short_trace():
        rng = np.random.default_rng(11)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, 8,
                                            dtype=np.int32),
                        max_new_tokens=4) for i in range(16)]

    peaks, util = {}, 0.0
    for name, scfg in (
        ("dense", ServeConfig(max_batch=4, s_max=128,
                              prefill_buckets=(16, 32))),
        ("paged", ServeConfig(max_batch=16, s_max=128,
                              prefill_buckets=(16, 32),
                              cache=PagedCache(page_size=16,
                                               num_pages=32))),
    ):
        eng = ServeEngine(model, params, get_strategy(strategy), scfg)
        for r in short_trace():
            eng.submit(r)
        done = eng.run()
        assert all(r.ok for r in done), f"{name} admission trace failed"
        peaks[name] = eng.stats["peak_active"]
        if name == "paged":
            util = eng.stats["kv"]["kv_util"]
    return [
        f"serve/dense_admitted,{peaks['dense']},rows",
        f"serve/paged_admitted,{peaks['paged']},rows",
        f"serve/paged_admitted_delta,{peaks['paged'] - peaks['dense']},"
        "rows",
        f"serve/paged_kv_util,{util:.3f},ratio",
    ]


def _spec_rows(model, params, strategy, cache: str, proposer: str,
               draft_k: int, repeats: int):
    """Plain greedy vs speculative decode on a repeat-heavy workload.

    The prompts are short phrases tiled to full prompt length; under
    greedy decode the smoke model's output streams settle into
    near-periodic tails, which is exactly the regime the n-gram drafter
    exploits (the spec-decode analogue of the summarization/code
    workloads real drafters are benchmarked on).  The spec engine runs
    on a PlanStore that is saved and reloaded after warm-up, so the
    measured engine must restore every verify bucket with zero
    ``lower()`` calls."""
    import os
    import tempfile

    from repro.core.plan_store import PlanStore
    from repro.core.strategies import get_strategy
    from repro.serve import (PagedCache, Request, ServeConfig, ServeEngine,
                             SpecConfig)

    base = [[20, 4], [17], [104], [11, 4]]
    prompts = [(b * 24)[:24] for b in base]
    max_new = 200

    def backend():
        return PagedCache(page_size=16) if cache == "paged" else None

    def make(spec, store=None):
        return ServeEngine(
            model, params, get_strategy(strategy),
            ServeConfig(max_batch=4, s_max=256, prefill_buckets=(32,),
                        cache=backend(), spec=spec),
            plan_store=store)

    def drive(eng, tag):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=tag * 100 + i,
                               prompt=np.asarray(p, np.int32),
                               max_new_tokens=max_new))
        t0 = time.perf_counter()
        done = eng.run(max_iters=200_000)
        dt = time.perf_counter() - t0
        outs = {r.rid % 100: list(r.output) for r in done[-len(prompts):]}
        return outs, dt

    spec_cfg = SpecConfig(proposer=proposer, k=draft_k)

    # cold spec engine populates a store; save/load it so the measured
    # engine restores the verify buckets instead of lowering them
    fd, store_path = tempfile.mkstemp(suffix=".dfps")
    os.close(fd)
    try:
        cold = make(spec_cfg, PlanStore())
        cold.warmup()
        cold.store.save(store_path)
        cold.shutdown()
        warm_store = PlanStore()
        warm_store.load(store_path)
    finally:
        os.unlink(store_path)

    plain = make(None)
    plain.warmup()
    drive(plain, 0)                              # unmeasured warm round
    spec = make(spec_cfg, warm_store)
    spec.warmup()
    verify_lowers = sum(b["misses"]
                        for b in spec.stats["spec_builds"].values())
    drive(spec, 0)                               # unmeasured warm round

    s0 = spec.stats
    syncs0, steps0 = s0["host_syncs"], s0["decode_steps"]
    p_best = s_best = None
    plain_out = spec_out = None
    toks = len(prompts) * max_new
    for rep in range(1, repeats + 1):
        plain_out, pdt = drive(plain, rep)
        spec_out, sdt = drive(spec, rep)
        p_best = pdt if p_best is None else min(p_best, pdt)
        s_best = sdt if s_best is None else min(s_best, sdt)
    assert plain_out == spec_out, \
        "speculative greedy decode diverged from plain greedy decode"
    st = spec.stats
    syncs = st["host_syncs"] - syncs0
    steps = st["decode_steps"] - steps0
    rate = st["spec_accepted"] / max(1, st["spec_drafted"])
    plain.shutdown()
    spec.shutdown()
    plain_tps = toks / p_best
    spec_tps = toks / s_best
    return [
        f"serve/spec_plain_tps,{plain_tps:.1f},tok/s",
        f"serve/spec_accepted_tps,{spec_tps:.1f},tok/s",
        f"serve/spec_speedup,{spec_tps / max(plain_tps, 1e-9):.2f},x",
        f"serve/spec_acceptance_rate,{rate:.3f},ratio",
        f"serve/spec_rollbacks,{st['spec_rollbacks']},count",
        f"serve/spec_fallbacks,{st['spec_fallbacks']},count",
        f"serve/spec_syncs_per_decode,{syncs / max(steps, 1):.3f},ratio",
        f"serve/spec_verify_lowers,{verify_lowers},count",
        f"serve/spec_draft_k,{draft_k},count",
    ]


def run(requests: int = 12, max_new: int = 6, strategy: str = "sequential",
        arch: str = "chatglm3-6b", repeats: int = 3, inject: bool = False,
        cache: str = "dense", spec: str = "off", draft_k: int = 4):
    import jax
    from repro.configs import get_smoke_config
    from repro.core.strategies import get_strategy
    from repro.models.layers import MeshInfo
    from repro.models.registry import build_model
    from repro.serve import PagedCache, ServeConfig, ServeEngine

    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=128)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    if spec != "off":
        return _spec_rows(model, params, strategy, cache, spec, draft_k,
                          repeats)
    backend = PagedCache(page_size=16) if cache == "paged" else None

    def engine(**kw):
        return ServeEngine(model, params, get_strategy(strategy),
                           ServeConfig(max_batch=8, s_max=128,
                                       prefill_buckets=(16, 32),
                                       cache=backend, **kw))

    tiered = engine()
    base = engine(decode_tiers=(8,), prefill_batch=1, async_host=False)

    # warm both engines (captures + jits) outside the measured window,
    # then take the best of `repeats` measured traces per engine
    for eng in (tiered, base):
        eng.warmup()
        _run_engine(eng, _trace(cfg, np.random.default_rng(99), 8, 3))
    t_res, b_res = [], []
    for rep in range(repeats):
        rng = np.random.default_rng(rep)
        t_res.append(_run_engine(tiered, _trace(cfg, rng, requests,
                                                max_new)))
        rng = np.random.default_rng(rep)
        b_res.append(_run_engine(base, _trace(cfg, rng, requests, max_new)))
    tr = max(t_res, key=lambda r: r["tps"])
    br = max(b_res, key=lambda r: r["tps"])

    st = tiered.stats
    bst = base.stats
    builds = st["tier_builds"]
    canonical = min(builds) if builds else None
    tier_shares = sum(b["shares"] for t, b in builds.items())
    tier_lowers = sum(b["misses"] for t, b in builds.items()
                      if t != canonical)
    out = [
        f"serve/baseline_tps,{br['tps']:.1f},tok/s",
        f"serve/tiered_tps,{tr['tps']:.1f},tok/s",
        f"serve/tiered_speedup,{tr['tps'] / max(br['tps'], 1e-9):.2f},x",
        f"serve/baseline_ttft_p50_ms,{br['ttft_p50_ms']:.1f},ms",
        f"serve/tiered_ttft_p50_ms,{tr['ttft_p50_ms']:.1f},ms",
        f"serve/decode_tier_shares,{tier_shares},count",
        f"serve/decode_tier_lowers,{tier_lowers},count",
        f"serve/chunk_steps,{st['chunk_steps']},count",
        f"serve/row_moves,{st['row_moves']},count",
        f"serve/tiered_syncs_per_decode,"
        f"{st['host_syncs'] / max(st['decode_steps'], 1):.3f},ratio",
        f"serve/baseline_syncs_per_decode,"
        f"{bst['host_syncs'] / max(bst['decode_steps'], 1):.3f},ratio",
    ]
    for t, n in sorted(st["tier_steps"].items()):
        out.append(f"serve/tier_steps_{t},{n},count")
    if cache == "paged":
        out.extend(_admission_rows(model, params, strategy, cfg))
    if inject:
        out.extend(_degraded_rows(engine, cfg, requests, max_new))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--strategy", default="sequential")
    ap.add_argument("--inject", action="store_true",
                    help="add a degraded-mode trace under injected faults")
    ap.add_argument("--cache", default="dense",
                    choices=("dense", "paged"),
                    help="KV cache backend; paged adds the equal-pool "
                         "admission comparison rows")
    ap.add_argument("--spec", default="off",
                    choices=("off", "ngram", "self"),
                    help="run the speculative-decode comparison with "
                         "this proposer instead of the standard trace")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft length for --spec runs")
    args = ap.parse_args()
    print("\n".join(run(requests=args.requests, max_new=args.max_new,
                        strategy=args.strategy, repeats=args.repeats,
                        inject=args.inject, cache=args.cache,
                        spec=args.spec, draft_k=args.draft_k)))
