"""Paper Fig. 2a analogue: batch-size sensitivity of batch splitting.

Splitting wins at large batch (collective overlap outweighs the extra
weight reads) and loses at small batch (the re-read penalty dominates) —
the property that forces DynaFlow's *dynamic* per-bucket choice.  The
same roofline overlap model, swept over batch sizes.
"""
from repro.configs import get_config
from repro.core import partition, record_plan
from repro.core.scheduler import ScheduleContext
from repro.core.strategies import get_strategy
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.roofline.overlap import plan_overlap, split_weight_penalty


def run():
    out = []
    cfg = get_config("chatglm3-6b")
    mesh = MeshInfo(tp=16, dp=16, attn_impl="chunked")
    model = build_model(cfg, mesh)
    # prefill (serving) phase: the paper's Fig. 2a setting — token count
    # is the split condition, so sweep (B, S) from tiny to large
    for B_loc, S in ((1, 2048), (2, 64), (2, 256), (2, 2048), (4, 2048),
                     (16, 2048), (64, 2048)):
        segs, _ = model.build_segments("prefill", B_loc, S, s_max=S)
        seg = [s for s in segs if s.count > 1][0]
        info = ScheduleContext(local_batch=B_loc, seq_len=S, phase="prefill",
                               arch=cfg.name)
        base = record_plan(seg.graph, get_strategy("sequential"), info)
        t_base = plan_overlap(seg.graph, base, tp=16).t_sequential
        if B_loc >= 2:
            split = record_plan(seg.graph,
                                get_strategy("nanoflow", min_tokens=1), info)
            pen = split_weight_penalty(seg.graph, split.num_mb)
            t_split = plan_overlap(seg.graph, split, tp=16,
                                   extra_weight_read_bytes=pen).t_overlapped
            rel = t_base / t_split
        else:
            rel = 1.0
        out.append(
            f"sensitivity/tokens_{B_loc * S},{rel:.3f},x_split_vs_seq")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
