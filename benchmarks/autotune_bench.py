"""Autotuner quality gate: auto >= the best hand-picked strategy.

For every (arch x phase x shape) cell, :class:`AutoPolicy` picks a
winner from the registry's candidate set (ranked with the roofline
overlap model); the gate checks it never loses to any single hand-picked
strategy *scored the same way on the same partitioned graph* — by
construction the argmin cannot lose, so a failure means the tuner and
the executor disagree about the graph or the objective (exactly the
regression this gate exists to catch).  Also exercised: verdicts persist
into a PlanStore artifact and a restarted process re-resolves every cell
with **zero** re-tunes, and every tuned plan's modeled time is bounded
by the sequential baseline.

  python benchmarks/autotune_bench.py            # CSV-ish report rows
  python benchmarks/autotune_bench.py --check    # CI gate (exit code)
"""
import os
import sys
import tempfile

from repro.configs import get_config
from repro.core.autotune import AutoPolicy
from repro.core.plan_store import PlanStore
from repro.core.policy import with_graph
from repro.core.scheduler import ScheduleContext, record_plan
from repro.core.strategies.registry import make_scheduler, \
    tunable_candidates
from repro.models.layers import MeshInfo
from repro.models.registry import build_model

ARCHS = ("chatglm3-6b", "deepseek-moe-16b")
# (phase, B_loc, S): small decode, large prefill — the two regimes whose
# winners differ (paper Fig. 2a), plus a mid shape per phase
SHAPES = (("prefill", 2, 256), ("prefill", 8, 2048),
          ("decode", 2, 128), ("decode", 64, 2048))
TP = 16


def _cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg, MeshInfo(tp=TP, dp=16,
                                          attn_impl="chunked"))
        for phase, B_loc, S in SHAPES:
            segs, _ = model.build_segments(phase, B_loc, 1 if phase ==
                                           "decode" else S, s_max=S)
            seg = max((s for s in segs if s.count > 1),
                      key=lambda s: len(s.graph.nodes))
            info = ScheduleContext(local_batch=B_loc, seq_len=S,
                                   phase=phase, arch=cfg.name,
                                   mesh_shape={"tp": TP, "dp": 16})
            yield arch, phase, B_loc, S, seg.graph, info


def _hand_picked(auto: AutoPolicy, graph, info):
    """(label, t) of every hand-picked candidate, scored on the same
    union-partitioned graph and objective the tuner used."""
    g = auto._tuning_graph(graph)
    rows = []
    for name, params in tunable_candidates():
        try:
            plan = record_plan(g, make_scheduler(name, **params), info)
            rep, _ = auto._score(g, plan, TP)
        except Exception:
            continue
        rows.append((name, rep.t_overlapped))
    return rows


def run(check: bool = False):
    out, failures = [], []
    store = PlanStore()
    auto = AutoPolicy(tp=TP)
    auto.bind_store(store)
    cells = list(_cells())
    for arch, phase, B_loc, S, graph, info in cells:
        auto(with_graph(info, graph))
        v = auto.lookup(info, graph)
        best_hand = min(_hand_picked(auto, graph, info),
                        key=lambda r: r[1])
        ratio = best_hand[1] / max(v.t_model, 1e-12)
        out.append(f"autotune/{arch}/{phase}_B{B_loc}_S{S},"
                   f"{ratio:.4f},x_best_hand_vs_auto,winner={v.winner}")
        if v.t_model > best_hand[1] * (1 + 1e-9):
            failures.append(
                f"{arch}/{phase} B={B_loc} S={S}: auto chose {v.winner} "
                f"({v.t_model:.3e}s) but hand-picked {best_hand[0]} is "
                f"faster ({best_hand[1]:.3e}s)")
        if v.t_model > v.t_sequential * (1 + 1e-9):
            failures.append(
                f"{arch}/{phase} B={B_loc} S={S}: tuned exposed time "
                f"{v.t_model:.3e}s exceeds sequential "
                f"{v.t_sequential:.3e}s")

    # restart: a fresh process (fresh policy + store) must inherit every
    # verdict from the artifact with zero re-tunes
    path = os.path.join(tempfile.mkdtemp(prefix="autotune-bench-"),
                        "plans.dfps")
    store.save(path)
    store2 = PlanStore()
    store2.load(path)
    auto2 = AutoPolicy(tp=TP)
    auto2.bind_store(store2)
    for arch, phase, B_loc, S, graph, info in cells:
        auto2(with_graph(info, graph))
    out.append(f"autotune/restart_retunes,{auto2.retunes},"
               f"count_over_{len(cells)}_cells")
    if auto2.retunes != 0:
        failures.append(
            f"restart re-tuned {auto2.retunes}/{len(cells)} cells; "
            "verdicts did not persist/reload")

    if check:
        for msg in failures:
            print(f"autotune-gate FAIL {msg}")
        for line in out:
            print(f"autotune-gate OK {line}")
        return 1 if failures else 0
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--check":
        sys.exit(run(check=True))
    print("\n".join(run()))
