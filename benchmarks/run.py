"""Benchmark harness — one module per paper table/figure.

  loc_table         Tables 1-2: engineering cost in LoC
  overhead          Fig. 8:     scheduling/dispatch CPU overhead
  throughput_model  Figs 9-12:  modeled strategy gains from real plans
  ablation          Fig. 14:    memory / graph / dynamic ablation
  sensitivity       Fig. 2a:    batch-size split sensitivity

Prints ``name,value,unit`` CSV lines.  Dry-run-derived rooflines live in
results/dryrun/*.json (written by repro.launch.dryrun).
"""
import sys
import time


def main() -> None:
    from benchmarks import ablation, loc_table, overhead, report, \
        sensitivity, throughput_model
    for mod in (loc_table, overhead, throughput_model, ablation,
                sensitivity, report):
        name = mod.__name__.split(".")[-1]
        print(f"# === {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
