"""Paper Figs. 9-12 analogue: modeled end-to-end gains per strategy.

This container is CPU-only, so throughput deltas are derived from the
plan-aware overlap model (roofline/overlap.py) applied to each strategy's
actual recorded plan over the real layer graphs — the TPU quantity the
strategies change is exposed collective/memory time, which the model
computes from the same per-op costs the roofline uses.

Reported: modeled step-time speedup vs the sequential plan for each
(arch × strategy), the paper's throughput-improvement analogue:
  Fig. 9  NanoFlow on dense archs
  Fig. 10 DBO on the MoE arch
  Fig. 11 comm-overlap (SBO) across families
  Fig. 12 TokenWeave / Comet fusion
"""
from repro.configs import get_config
from repro.core import partition, record_plan
from repro.core.scheduler import ScheduleContext
from repro.core.strategies import get_strategy
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.roofline.overlap import plan_overlap, split_weight_penalty

# Serving-phase cases mirror the paper's vLLM/SGLang setting (prefill,
# TP collectives proportional to activations); two train cases cover the
# Megatron-style rows of Fig. 11/12.
CASES = [
    # (figure, arch, phase, strategy, B_loc, S)
    ("fig9_nanoflow", "chatglm3-6b", "prefill", "nanoflow", 8, 2048),
    ("fig9_nanoflow", "minitron-8b", "prefill", "nanoflow", 8, 2048),
    ("fig9_nanoflow", "qwen2-vl-7b", "prefill", "nanoflow", 8, 2048),
    ("fig10_dbo", "deepseek-moe-16b", "prefill", "dbo", 8, 2048),
    ("fig10_dbo", "grok-1-314b", "prefill", "dbo", 8, 2048),
    ("fig11_sbo", "deepseek-moe-16b", "prefill", "sbo", 8, 2048),
    ("fig11_sbo", "chatglm3-6b", "prefill", "sbo", 8, 2048),
    ("fig11_sbo_train", "deepseek-moe-16b", "train", "sbo", 16, 4096),
    ("fig11_sbo_train", "grok-1-314b", "train", "sbo", 16, 4096),
    ("fig12_tokenweave", "smollm-135m", "prefill", "tokenweave", 8, 2048),
    ("fig12_tokenweave", "whisper-tiny", "prefill", "tokenweave", 8, 2048),
    ("fig12_comet", "deepseek-moe-16b", "prefill", "comet", 8, 2048),
    ("fig12_comet_train", "deepseek-moe-16b", "train", "comet", 16, 4096),
    ("fig12_flux", "smollm-135m", "prefill", "flux", 8, 2048),
    # Appendix B: DBO under a low-bandwidth fabric (multi-node DCN; the
    # paper simulates this with PCIe and reports up to 2.06x)
    ("appB_dbo_lowbw", "deepseek-moe-16b", "prefill", "dbo", 8, 2048),
    ("appB_dbo_lowbw", "grok-1-314b", "prefill", "dbo", 8, 2048),
]


def model_case(arch, phase, strategy, B_loc, S, tp=16, bw_scale=1.0):
    cfg = get_config(arch)
    mesh = MeshInfo(tp=tp, dp=16, attn_impl="chunked")
    model = build_model(cfg, mesh)
    segs, _ = model.build_segments(phase, B_loc, S, s_max=S)
    stacks = [s for s in segs if s.count > 1] or segs[1:-1] or segs
    seg = max(stacks, key=lambda s: len(s.graph.nodes))
    info = ScheduleContext(local_batch=B_loc, seq_len=S, phase=phase,
                           arch=arch)

    def report(strat_name, **kw):
        strat = get_strategy(strat_name, **kw)
        g = seg.graph
        if strat.partition_rules():
            g = partition(g, strat.partition_rules(), default_depth=2)
        plan = record_plan(g, strat, info)
        pen = split_weight_penalty(g, plan.num_mb)
        return plan_overlap(g, plan, tp=tp, extra_weight_read_bytes=pen,
                            bw_scale=bw_scale)

    base = report("sequential")
    got = report(strategy) if strategy not in ("nanoflow", "dbo") \
        else report(strategy, min_tokens=1)
    return base, got


def run():
    out = []
    for fig, arch, phase, strat, B, S in CASES:
        try:
            bw = 0.125 if fig.startswith("appB") else 1.0
            base, got = model_case(arch, phase, strat, B, S, bw_scale=bw)
            speed = base.t_sequential / max(got.t_overlapped, 1e-12)
            out.append(
                f"{fig}/{arch},{speed:.3f},x_modeled"
                f" (coll {base.coll_total*1e3:.2f}ms ->"
                f" exposed {got.coll_exposed*1e3:.2f}ms)")
        except Exception as e:                        # pragma: no cover
            out.append(f"{fig}/{arch},ERROR,{type(e).__name__}:{e}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
