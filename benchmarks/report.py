"""Roofline report: renders results/dryrun/*.json (written by
repro.launch.dryrun) as the §Roofline table — baseline and, where
present, the optimized (--attn-sub / resident-ZeRO) counterpart."""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run():
    out = []
    files = sorted(f for f in glob.glob(os.path.join(RESULTS, "*.json"))
                   if "__pallas" not in f)
    if not files:
        return ["roofline/report,SKIPPED,run repro.launch.dryrun first"]
    agg_base = agg_opt = 0.0
    n_opt = 0
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        line = (f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                f"c={rl['t_compute']:.3f}s m={rl['t_memory']:.3f}s "
                f"coll={rl['t_collective']:.4f}s,"
                f"bott={rl['bottleneck']} useful={rl['useful_ratio']:.3f} "
                f"peak={r['memory']['peak_per_device']/2**30:.1f}GiB")
        pf = f.replace(".json", "__pallas.json")
        if os.path.exists(pf):
            o = json.load(open(pf))["roofline"]
            line += (f" | opt: c={o['t_compute']:.3f} m={o['t_memory']:.3f} "
                     f"coll={o['t_collective']:.4f}")
            agg_base += rl["t_bound"]
            agg_opt += o["t_bound"]
            n_opt += 1
        out.append(line)
    if n_opt:
        out.append(f"roofline/aggregate,{agg_base:.1f}s->{agg_opt:.1f}s,"
                   f"bound-step sum over {n_opt} cells "
                   f"({agg_base/agg_opt:.2f}x)")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
